"""Allocator unit + property tests (paper §3.4 invariants).

The property tests prefer ``hypothesis``; when it is not installed they fall
back to the same checks over seeded pseudo-random operation sequences, so the
suite collects and runs from a clean environment (test deps are pinned in
``requirements.txt`` / ``pyproject.toml``).
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core.allocator import BalancedAllocator as BA
from repro.core.allocator import GenericAllocator as GA
from repro.core.allocator import SizeClassAllocator as SC
from repro.core.allocator import FAIL, find_obj_linear


def _states_equal(a, b) -> bool:
    fa, ta = jax.tree_util.tree_flatten(a)
    fb, tb = jax.tree_util.tree_flatten(b)
    return ta == tb and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(fa, fb))


# ---------------------------------------------------------------------------
# Generic allocator
# ---------------------------------------------------------------------------

def test_generic_basic():
    s = GA.init(1000, cap=16)
    s, p1 = GA.malloc(s, 100)
    s, p2 = GA.malloc(s, 50)
    assert int(p1) == 0 and int(p2) == 100
    s = GA.free(s, p1)
    s, p3 = GA.malloc(s, 80)        # first-fit reuse of p1's hole
    assert int(p3) == 0
    found, base, size = GA.find_obj(s, p2 + 49)
    assert bool(found) and int(base) == 100 and int(size) == 50
    found, _, _ = GA.find_obj(s, 999)
    assert not bool(found)


def test_generic_oom():
    s = GA.init(100, cap=4)
    s, p1 = GA.malloc(s, 100)
    s, p2 = GA.malloc(s, 1)
    assert int(p1) == 0 and int(p2) == -1


def test_generic_reuse_records_requested_size():
    """Regression: first-fit reuse must report the REQUESTED size via
    find_obj (v1 left the stale capacity, so RPC shipped the wrong extent)."""
    s = GA.init(1000, cap=16)
    s, p = GA.malloc(s, 100)
    s = GA.free(s, p)
    s, q = GA.malloc(s, 30)            # reuses the 100-hole
    assert int(q) == int(p)
    found, base, size = GA.find_obj(s, q)
    assert bool(found) and int(base) == int(q) and int(size) == 30
    # the hole keeps its CAPACITY: free + a larger (but fitting) request
    # still reuses it
    s = GA.free(s, q)
    s, r = GA.malloc(s, 100)
    assert int(r) == int(p)


def test_generic_nonpositive_size_fails():
    s = GA.init(100, cap=4)
    before = s
    s, p = GA.malloc(s, 0)
    assert int(p) == -1 and _states_equal(s, before)
    s, p = GA.malloc(s, -3)
    assert int(p) == -1 and _states_equal(s, before)


def test_generic_free_invalid_ptr_noop():
    s = GA.init(100, cap=4)
    s, p = GA.malloc(s, 10)
    before = s
    for bad in (-1, -7, 100, 5000):    # FAIL and out-of-arena
        assert _states_equal(GA.free(s, bad), before)
        found, _, _ = GA.find_obj(s, bad)
        assert not bool(found)


def test_generic_bulk_matches_serial_including_failures():
    """The prefix-sum bulk path must equal the serial scan bit-for-bit on
    the watermark path — including a large failing request followed by small
    requests that still fit (the fixed-point case)."""
    sizes = jnp.asarray([30, 30, 50, 20, 15, 90, 5], jnp.int32)
    s_bulk, p_bulk = jax.jit(GA.malloc_many)(GA.init(100, cap=16), sizes)
    s_ser, p_ser = GA.malloc_many_serial(GA.init(100, cap=16), sizes)
    assert list(np.asarray(p_bulk)) == list(np.asarray(p_ser))
    assert _states_equal(s_bulk, s_ser)
    # zero/negative sizes are skipped in place
    sizes = jnp.asarray([8, 0, 8, -2, 8], jnp.int32)
    _, ptrs = GA.malloc_many(GA.init(100, cap=16), sizes)
    assert list(np.asarray(ptrs)) == [0, -1, 8, -1, 16]


def test_generic_free_many_vectorized():
    s = GA.init(1000, cap=32)
    s, ptrs = GA.malloc_many(s, jnp.full((6,), 10, jnp.int32))
    s = jax.jit(GA.free_many)(s, ptrs[::2])
    for i, p in enumerate(np.asarray(ptrs)):
        found, _, _ = GA.find_obj(s, int(p))
        assert bool(found) == (i % 2 == 1)
    # FAIL entries in the batch are ignored
    before = s
    assert _states_equal(GA.free_many(s, jnp.asarray([-1, 999], jnp.int32)),
                         before)


def test_generic_malloc_many_inside_jit():
    s = GA.init(1000, cap=64)
    sizes = jnp.full((10,), 10, jnp.int32)
    s, ptrs = jax.jit(GA.malloc_many)(s, sizes)
    assert list(np.asarray(ptrs)) == [i * 10 for i in range(10)]
    s = GA.free_many(s, ptrs[::2])
    s, p = GA.malloc(s, 10)
    assert int(p) in {0, 20, 40, 60, 80}


# ---------------------------------------------------------------------------
# Balanced allocator
# ---------------------------------------------------------------------------

def test_balanced_chunking_and_reclaim():
    s = BA.init(8000, 4, 2, cap=8, first_chunk_ratio=2.0)
    # chunk 0 is larger than chunk 1
    assert int(s.chunk_size[0]) > int(s.chunk_size[1])
    s, a = BA.malloc(s, 0, 0, 64)
    s, b = BA.malloc(s, 0, 0, 32)
    s, c = BA.malloc(s, 1, 0, 16)       # different chunk: independent
    assert int(c) == int(s.chunk_start[2])
    # free middle: not reclaimed (watermark stays)
    wm_before = int(s.watermark[0])
    s = BA.free(s, a)
    assert int(s.watermark[0]) == wm_before
    # free top: reclaims top AND the already-freed middle below it (Fig. 5)
    s = BA.free(s, b)
    assert int(s.watermark[0]) == 0
    assert int(s.count[0]) == 0


def test_balanced_hole_reuse_when_full():
    s = BA.init(80, 2, 1, cap=8, first_chunk_ratio=1.0)  # chunks of 40
    s, a = BA.malloc(s, 0, 0, 30)
    s, b = BA.malloc(s, 0, 0, 10)      # chunk 0 now full
    s = BA.free(s, a)                   # hole (not top)
    s, c = BA.malloc(s, 0, 0, 25)      # must reuse the 30-hole
    assert int(c) == int(a)


def test_balanced_find_obj():
    s = BA.init(8000, 4, 2, cap=8)
    s, a = BA.malloc(s, 2, 1, 64)
    found, base, size = BA.find_obj(s, a + 63)
    assert bool(found) and int(base) == int(a) and int(size) == 64
    found, _, _ = BA.find_obj(s, a + 64)
    assert not bool(found)


def test_balanced_grid_parallel():
    s = BA.init(100000, 4, 2, cap=16)
    sizes = jnp.full((8, 4), 10, jnp.int32)
    s, ptrs = jax.jit(BA.malloc_grid, static_argnums=(1, 2))(s, 8, 4, sizes)
    arr = np.asarray(ptrs).ravel()
    assert (arr >= 0).all()
    assert len(np.unique(arr)) == arr.size          # all distinct
    s = BA.free_grid(s, 8, 4, ptrs)
    assert int(jnp.max(s.watermark)) == 0            # everything reclaimed


def test_balanced_free_invalid_ptr_noop():
    """Regression: free/find_obj of FAIL (-1) or out-of-arena pointers used
    to clamp into chunk 0 / the last chunk — they must be guaranteed
    no-ops."""
    s = BA.init(8000, 4, 2, cap=8)
    s, a = BA.malloc(s, 0, 0, 64)
    s, b = BA.malloc(s, 3, 1, 32)
    before = s
    heap_end = int(s.chunk_start[-1]) + int(s.chunk_size[-1])
    for bad in (-1, -100, heap_end, heap_end + 17):
        assert _states_equal(jax.jit(BA.free)(s, bad), before)
        found, _, _ = BA.find_obj(s, bad)
        assert not bool(found)
    # the live objects are untouched and still found
    for ptr, size in ((a, 64), (b, 32)):
        found, base, fsize = BA.find_obj(s, ptr)
        assert bool(found) and int(base) == int(ptr) and int(fsize) == size


def test_balanced_reuse_records_requested_size():
    s = BA.init(80, 2, 1, cap=8, first_chunk_ratio=1.0)  # chunks of 40
    s, a = BA.malloc(s, 0, 0, 30)
    s, _ = BA.malloc(s, 0, 0, 10)
    s = BA.free(s, a)
    s, c = BA.malloc(s, 0, 0, 25)      # reuses the 30-hole
    assert int(c) == int(a)
    found, base, size = BA.find_obj(s, c)
    assert bool(found) and int(base) == int(c) and int(size) == 25


def test_balanced_grid_bulk_matches_scan():
    """The vectorized grid paths must reproduce the v1 per-chunk scan on
    fresh space — pointers and final state bit-for-bit."""
    sizes = jnp.arange(1, 33, dtype=jnp.int32).reshape(8, 4)
    s1, p1 = jax.jit(BA.malloc_grid, static_argnums=(1, 2))(
        BA.init(100000, 4, 2, cap=16), 8, 4, sizes)
    s2, p2 = jax.jit(BA.malloc_grid_scan, static_argnums=(1, 2))(
        BA.init(100000, 4, 2, cap=16), 8, 4, sizes)
    assert np.array_equal(np.asarray(p1), np.asarray(p2))
    assert _states_equal(s1, s2)
    f1 = BA.free_grid(s1, 8, 4, p1)
    f2 = BA.free_grid_scan(s2, 8, 4, p2)
    assert _states_equal(f1, f2)
    assert int(jnp.max(f1.watermark)) == 0


def test_balanced_grid_skips_and_failures():
    # chunk capacity 10 entries; per-chunk stream mixes skip (0), fits, and
    # an over-sized request that must not block later fits
    s = BA.init(40, 2, 1, cap=10, first_chunk_ratio=1.0)   # chunks of 20
    sizes = jnp.asarray([[8], [0], [50], [8],
                         [8], [0], [50], [8]], jnp.int32)  # tid-major
    s, ptrs = BA.malloc_grid(s, 8, 1, sizes)
    got = np.asarray(ptrs).ravel()
    # tid 0,2,4,6 -> chunk 0; tid 1,3,5,7 -> chunk 1 (tid % 2)
    assert got[2] == -1 and got[6] == -1          # oversized fail
    assert got[1] == -1 and got[5] == -1          # size-0 skip
    assert (got[[0, 4]] >= 0).all() and (got[[3, 7]] >= 0).all()
    found, _, size = BA.find_obj(s, int(got[4]))
    assert bool(found) and int(size) == 8


def test_balanced_reset_chunks_bulk():
    s = BA.init(8000, 4, 1, cap=8, first_chunk_ratio=1.0)
    ptrs = []
    for tid in range(4):
        s, p = BA.malloc(s, tid, 0, 16)
        ptrs.append(int(p))
    s = BA.reset_chunks(s, jnp.asarray([True, False, True, False]))
    assert int(s.count[0]) == 0 and int(s.watermark[0]) == 0
    assert int(s.count[1]) == 1 and int(s.watermark[1]) == 16
    for tid, p in enumerate(ptrs):
        found, _, _ = BA.find_obj(s, p)
        assert bool(found) == (tid % 2 == 1)


# ---------------------------------------------------------------------------
# Size-class allocator (v2)
# ---------------------------------------------------------------------------

def test_sizeclass_basic_and_bin_reuse():
    s = SC.init(1000, cap=64)
    s, p1 = SC.malloc(s, 100)
    s, p2 = SC.malloc(s, 50)
    assert int(p1) == 0 and int(p2) == 100
    found, base, size = SC.find_obj(s, p2 + 49)
    assert bool(found) and int(base) == 100 and int(size) == 50
    s = SC.free(s, p1)
    found, _, _ = SC.find_obj(s, p1)
    assert not bool(found)
    # binned reuse: a request within the freed block's class comes from the
    # bin (same base), not the watermark
    wm = int(s.watermark)
    s, p3 = SC.malloc(s, 60)           # ceil class 6 == the 100-block's class
    assert int(p3) == int(p1) and int(s.watermark) == wm
    found, base, size = SC.find_obj(s, p3)
    assert bool(found) and int(size) == 60    # requested, not capacity


def test_sizeclass_class_guarantee():
    """Segregated fit never hands out a too-small block."""
    s = SC.init(1000, cap=64)
    s, small = SC.malloc(s, 5)
    s, _ = SC.malloc(s, 1)             # pin the watermark above `small`
    s = SC.free(s, small)
    s, p = SC.malloc(s, 6)             # 6 > 5: must NOT reuse the 5-block
    assert int(p) != int(small)
    found, _, size = SC.find_obj(s, p)
    assert bool(found) and int(size) == 6


def test_sizeclass_invalid_ops_noop():
    s = SC.init(100, cap=16)
    s, p = SC.malloc(s, 10)
    before = s
    for bad in (-1, 100, 7777):
        assert _states_equal(SC.free(s, bad), before)
        found, _, _ = SC.find_obj(s, bad)
        assert not bool(found)
    s, q = SC.malloc(s, 0)
    assert int(q) == -1 and _states_equal(s, before)


def test_sizeclass_bulk_roundtrip():
    s = SC.init(4096, cap=256)
    sizes = jnp.full((100,), 8, jnp.int32)
    s, ptrs = jax.jit(SC.malloc_many)(s, sizes)
    arr = np.asarray(ptrs)
    assert (arr >= 0).all() and len(np.unique(arr)) == arr.size
    s = jax.jit(SC.free_many)(s, ptrs)
    # every block is binned: the next 100 singles all reuse, watermark fixed
    wm = int(s.watermark)
    for _ in range(4):
        s, p = SC.malloc(s, 8)
        assert int(p) >= 0
    assert int(s.watermark) == wm


def test_find_obj_matches_linear_reference():
    """The O(log) sorted-index lookup agrees with the v1 linear scan
    everywhere (live, freed, interior, boundary, invalid)."""
    g = GA.init(500, cap=32)
    g, ptrs = GA.malloc_many(g, jnp.asarray([7, 13, 1, 40, 9], jnp.int32))
    g = GA.free(g, int(np.asarray(ptrs)[1]))
    b = BA.init(1024, 4, 2, cap=16)
    for tid, team, size in [(0, 0, 9), (0, 0, 4), (3, 1, 30), (2, 0, 5)]:
        b, _ = BA.malloc(b, tid, team, size)
    probes = list(range(0, 120, 3)) + [500, 1023, -1]
    for st in (g, b):
        A = GA if isinstance(st, type(g)) else BA
        for ptr in probes:
            f1, b1, s1 = A.find_obj(st, ptr)
            f2, b2, s2 = find_obj_linear(st, ptr)
            assert bool(f1) == bool(f2), (type(st), ptr)
            if bool(f1):
                assert int(b1) == int(b2) and int(s1) == int(s2)


# ---------------------------------------------------------------------------
# Property tests: no two live allocations overlap; find_obj is exact
# ---------------------------------------------------------------------------

def _random_generic_ops(seed: int):
    rng = random.Random(seed)
    return [(rng.choice(["malloc", "free"]), rng.randint(1, 40),
             rng.randint(0, 7)) for _ in range(rng.randint(1, 30))]


def _random_balanced_ops(seed: int):
    rng = random.Random(seed)
    return [(rng.choice(["malloc", "free"]), rng.randint(1, 30),
             rng.randint(0, 3), rng.randint(0, 1), rng.randint(0, 7))
            for _ in range(rng.randint(1, 25))]


def _check_generic_no_overlap(ops):
    s = GA.init(512, cap=64)
    live = {}
    for kind, size, idx in ops:
        if kind == "malloc":
            s, p = GA.malloc(s, size)
            p = int(p)
            if p >= 0:
                live[p] = size
        elif live:
            keys = sorted(live)
            victim = keys[idx % len(keys)]
            s = GA.free(s, victim)
            del live[victim]
    # live allocations must be disjoint and inside the heap
    spans = sorted((p, p + sz) for p, sz in live.items())
    for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
        assert a1 <= b0, (spans,)
    for p, sz in live.items():
        assert p + sz <= 512
        found, base, fsize = GA.find_obj(s, p + sz // 2)
        # v2 records the REQUESTED size even on first-fit reuse (the hole's
        # capacity is tracked separately), so find_obj is exact
        assert bool(found) and int(base) == p and int(fsize) == sz


def _check_balanced_no_overlap(ops):
    s = BA.init(1024, 4, 2, cap=32, first_chunk_ratio=2.0)
    live = {}
    for kind, size, tid, team, idx in ops:
        if kind == "malloc":
            s, p = BA.malloc(s, tid, team, size)
            p = int(p)
            if p >= 0:
                live[p] = size
        elif live:
            keys = sorted(live)
            victim = keys[idx % len(keys)]
            s = BA.free(s, victim)
            del live[victim]
    spans = sorted((p, p + sz) for p, sz in live.items())
    for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
        assert a1 <= b0, (spans,)
    for p, sz in live.items():
        found, base, fsize = BA.find_obj(s, p)
        assert bool(found) and int(base) == p and int(fsize) == sz
    # allocations stay inside their chunk
    starts = np.asarray(s.chunk_start)
    sizes_ = np.asarray(s.chunk_size)
    for p, sz in live.items():
        c = int(np.searchsorted(starts, p, side="right")) - 1
        assert p + sz <= int(starts[c]) + int(sizes_[c])


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.lists(
        st.tuples(st.sampled_from(["malloc", "free"]),
                  st.integers(1, 40), st.integers(0, 7)),
        min_size=1, max_size=30))
    def test_generic_no_overlap_property(ops):
        _check_generic_no_overlap(ops)

    @settings(max_examples=25, deadline=None)
    @given(st.lists(
        st.tuples(st.sampled_from(["malloc", "free"]),
                  st.integers(1, 30), st.integers(0, 3), st.integers(0, 1),
                  st.integers(0, 7)),
        min_size=1, max_size=25))
    def test_balanced_no_overlap_property(ops):
        _check_balanced_no_overlap(ops)
else:
    @pytest.mark.parametrize("seed", range(12))
    def test_generic_no_overlap_property(seed):
        _check_generic_no_overlap(_random_generic_ops(seed))

    @pytest.mark.parametrize("seed", range(10))
    def test_balanced_no_overlap_property(seed):
        _check_balanced_no_overlap(_random_balanced_ops(seed))
