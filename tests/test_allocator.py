"""Allocator unit + property tests (paper §3.4 invariants).

The property tests prefer ``hypothesis``; when it is not installed they fall
back to the same checks over seeded pseudo-random operation sequences, so the
suite collects and runs from a clean environment (test deps are pinned in
``requirements.txt`` / ``pyproject.toml``).
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core.allocator import BalancedAllocator as BA
from repro.core.allocator import GenericAllocator as GA


# ---------------------------------------------------------------------------
# Generic allocator
# ---------------------------------------------------------------------------

def test_generic_basic():
    s = GA.init(1000, cap=16)
    s, p1 = GA.malloc(s, 100)
    s, p2 = GA.malloc(s, 50)
    assert int(p1) == 0 and int(p2) == 100
    s = GA.free(s, p1)
    s, p3 = GA.malloc(s, 80)        # first-fit reuse of p1's hole
    assert int(p3) == 0
    found, base, size = GA.find_obj(s, p2 + 49)
    assert bool(found) and int(base) == 100 and int(size) == 50
    found, _, _ = GA.find_obj(s, 999)
    assert not bool(found)


def test_generic_oom():
    s = GA.init(100, cap=4)
    s, p1 = GA.malloc(s, 100)
    s, p2 = GA.malloc(s, 1)
    assert int(p1) == 0 and int(p2) == -1


def test_generic_malloc_many_inside_jit():
    s = GA.init(1000, cap=64)
    sizes = jnp.full((10,), 10, jnp.int32)
    s, ptrs = jax.jit(GA.malloc_many)(s, sizes)
    assert list(np.asarray(ptrs)) == [i * 10 for i in range(10)]
    s = GA.free_many(s, ptrs[::2])
    s, p = GA.malloc(s, 10)
    assert int(p) in {0, 20, 40, 60, 80}


# ---------------------------------------------------------------------------
# Balanced allocator
# ---------------------------------------------------------------------------

def test_balanced_chunking_and_reclaim():
    s = BA.init(8000, 4, 2, cap=8, first_chunk_ratio=2.0)
    # chunk 0 is larger than chunk 1
    assert int(s.chunk_size[0]) > int(s.chunk_size[1])
    s, a = BA.malloc(s, 0, 0, 64)
    s, b = BA.malloc(s, 0, 0, 32)
    s, c = BA.malloc(s, 1, 0, 16)       # different chunk: independent
    assert int(c) == int(s.chunk_start[2])
    # free middle: not reclaimed (watermark stays)
    wm_before = int(s.watermark[0])
    s = BA.free(s, a)
    assert int(s.watermark[0]) == wm_before
    # free top: reclaims top AND the already-freed middle below it (Fig. 5)
    s = BA.free(s, b)
    assert int(s.watermark[0]) == 0
    assert int(s.count[0]) == 0


def test_balanced_hole_reuse_when_full():
    s = BA.init(80, 2, 1, cap=8, first_chunk_ratio=1.0)  # chunks of 40
    s, a = BA.malloc(s, 0, 0, 30)
    s, b = BA.malloc(s, 0, 0, 10)      # chunk 0 now full
    s = BA.free(s, a)                   # hole (not top)
    s, c = BA.malloc(s, 0, 0, 25)      # must reuse the 30-hole
    assert int(c) == int(a)


def test_balanced_find_obj():
    s = BA.init(8000, 4, 2, cap=8)
    s, a = BA.malloc(s, 2, 1, 64)
    found, base, size = BA.find_obj(s, a + 63)
    assert bool(found) and int(base) == int(a) and int(size) == 64
    found, _, _ = BA.find_obj(s, a + 64)
    assert not bool(found)


def test_balanced_grid_parallel():
    s = BA.init(100000, 4, 2, cap=16)
    sizes = jnp.full((8, 4), 10, jnp.int32)
    s, ptrs = jax.jit(BA.malloc_grid, static_argnums=(1, 2))(s, 8, 4, sizes)
    arr = np.asarray(ptrs).ravel()
    assert (arr >= 0).all()
    assert len(np.unique(arr)) == arr.size          # all distinct
    s = BA.free_grid(s, 8, 4, ptrs)
    assert int(jnp.max(s.watermark)) == 0            # everything reclaimed


# ---------------------------------------------------------------------------
# Property tests: no two live allocations overlap; find_obj is exact
# ---------------------------------------------------------------------------

def _random_generic_ops(seed: int):
    rng = random.Random(seed)
    return [(rng.choice(["malloc", "free"]), rng.randint(1, 40),
             rng.randint(0, 7)) for _ in range(rng.randint(1, 30))]


def _random_balanced_ops(seed: int):
    rng = random.Random(seed)
    return [(rng.choice(["malloc", "free"]), rng.randint(1, 30),
             rng.randint(0, 3), rng.randint(0, 1), rng.randint(0, 7))
            for _ in range(rng.randint(1, 25))]


def _check_generic_no_overlap(ops):
    s = GA.init(512, cap=64)
    live = {}
    for kind, size, idx in ops:
        if kind == "malloc":
            s, p = GA.malloc(s, size)
            p = int(p)
            if p >= 0:
                live[p] = size
        elif live:
            keys = sorted(live)
            victim = keys[idx % len(keys)]
            s = GA.free(s, victim)
            del live[victim]
    # live allocations must be disjoint and inside the heap
    spans = sorted((p, p + sz) for p, sz in live.items())
    for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
        assert a1 <= b0, (spans,)
    for p, sz in live.items():
        assert p + sz <= 512
        found, base, fsize = GA.find_obj(s, p + sz // 2)
        # first-fit reuse hands out the ORIGINAL (>=) block size — internal
        # fragmentation by design (paper §3.4)
        assert bool(found) and int(base) == p and int(fsize) >= sz


def _check_balanced_no_overlap(ops):
    s = BA.init(1024, 4, 2, cap=32, first_chunk_ratio=2.0)
    live = {}
    for kind, size, tid, team, idx in ops:
        if kind == "malloc":
            s, p = BA.malloc(s, tid, team, size)
            p = int(p)
            if p >= 0:
                live[p] = size
        elif live:
            keys = sorted(live)
            victim = keys[idx % len(keys)]
            s = BA.free(s, victim)
            del live[victim]
    spans = sorted((p, p + sz) for p, sz in live.items())
    for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
        assert a1 <= b0, (spans,)
    for p, sz in live.items():
        found, base, fsize = BA.find_obj(s, p)
        assert bool(found) and int(base) == p and int(fsize) >= sz
    # allocations stay inside their chunk
    starts = np.asarray(s.chunk_start)
    sizes_ = np.asarray(s.chunk_size)
    for p, sz in live.items():
        c = int(np.searchsorted(starts, p, side="right")) - 1
        assert p + sz <= int(starts[c]) + int(sizes_[c])


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.lists(
        st.tuples(st.sampled_from(["malloc", "free"]),
                  st.integers(1, 40), st.integers(0, 7)),
        min_size=1, max_size=30))
    def test_generic_no_overlap_property(ops):
        _check_generic_no_overlap(ops)

    @settings(max_examples=25, deadline=None)
    @given(st.lists(
        st.tuples(st.sampled_from(["malloc", "free"]),
                  st.integers(1, 30), st.integers(0, 3), st.integers(0, 1),
                  st.integers(0, 7)),
        min_size=1, max_size=25))
    def test_balanced_no_overlap_property(ops):
        _check_balanced_no_overlap(ops)
else:
    @pytest.mark.parametrize("seed", range(12))
    def test_generic_no_overlap_property(seed):
        _check_generic_no_overlap(_random_generic_ops(seed))

    @pytest.mark.parametrize("seed", range(10))
    def test_balanced_no_overlap_property(seed):
        _check_balanced_no_overlap(_random_balanced_ops(seed))
