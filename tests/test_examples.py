"""Examples as the linter's negative corpus (ISSUE 6 satellite).

Both ``examples/`` scripts run end-to-end under ``JAX_PLATFORMS=cpu``
(conftest pins it) inside the analyzer's event capture and must report
ZERO hazards — the standing false-positive fence for every rule the
analyzer grows.
"""
import importlib.util
import os

import pytest

from repro.analysis import analyze

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
EXAMPLES = os.path.join(ROOT, "examples")


def _load_main(filename):
    path = os.path.join(EXAMPLES, filename)
    name = f"_example_{filename.removesuffix('.py')}"
    spec = importlib.util.spec_from_file_location(name, path)
    module = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(module)
    return module.main


@pytest.mark.parametrize("filename", ["quickstart.py",
                                      "gpu_first_port.py"])
def test_example_reports_zero_hazards(filename, capsys):
    main = _load_main(filename)
    report = analyze(main, jaxpr=False)
    assert not report, f"{filename}:\n{report.summary()}"
