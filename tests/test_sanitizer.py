"""Runtime sanitizer (ISSUE 6 tentpole, runtime half).

Seeded defects must trip NAMED counters at flush: a payload canary stomp,
a use-after-free marshalling poisoned heap words, an ``ArenaRef`` resolved
against a freed block, a stale host-side reply read.  And the whole mode
must be free: on hazard-free programs ``sanitize=True`` delivers
bit-identical outputs and host records — only the queue-internal arena
layout (canary brackets) differs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.analysis.sanitize import POISON, poison_free
from repro.core.expand import expand, set_team_queue, team_queue
from repro.core.rpc import (ArenaRef, READ, REGISTRY, RpcQueue,
                            ShardedRpcQueue, reset_sanitize_stats,
                            rpc_call, sanitize_stats)

I32 = jax.ShapeDtypeStruct((), jnp.int32)

RECS = []


def _rec(*args):
    RECS.append(tuple(np.asarray(a).tolist() for a in args))


def _probe(ptr, base, size, found, arena):
    return np.int32(found)


REGISTRY.register("san.rec", _rec)
REGISTRY.register("san.probe", _probe)
REGISTRY.register("san.echo", lambda x: np.int32(x))


@pytest.fixture(autouse=True)
def _fresh():
    RECS.clear()
    reset_sanitize_stats()
    yield


def test_sanitized_flush_clean_and_transparent():
    """Hazard-free program: zero counters, records identical to plain."""
    def run(sanitize):
        RECS.clear()
        q = RpcQueue.create(8, 4, 64, sanitize=sanitize)
        q = q.enqueue("san.rec", jnp.int32(3), jnp.arange(5))
        q = q.enqueue("san.rec", jnp.float32(1.5))
        q.flush()
        return list(RECS)

    plain = run(False)
    sanitized = run(True)
    assert sanitized == plain and len(plain) == 2
    st = sanitize_stats()
    assert st["canary_stomps"] == 0 and st["poison_hits"] == 0
    assert len(st["epochs"]) == 1
    assert st["epochs"][0]["records"] == 2


def test_canary_stomp_detected_at_flush():
    q = RpcQueue.create(8, 4, 64, sanitize=True)
    q = q.enqueue("san.rec", jnp.arange(6))
    # payload layout: [canary, 6 words, canary] — stomp the leading canary
    q = dataclasses.replace(q, pbuf=q.pbuf.at[0].set(jnp.int32(0)))
    q.flush()
    assert sanitize_stats()["canary_stomps"] >= 1


def test_overrun_into_trailing_canary_detected():
    q = RpcQueue.create(8, 4, 64, sanitize=True)
    q = q.enqueue("san.rec", jnp.arange(4))
    # a 4-word reservation sits at words 1..4; word 5 is its canary
    q = dataclasses.replace(q, pbuf=q.pbuf.at[5].set(jnp.int32(7)))
    q.flush()
    assert sanitize_stats()["canary_stomps"] >= 1


def test_poison_free_uaf_hits_at_flush():
    """The seeded use-after-free: free a block, marshal its stale bytes."""
    from repro.core.allocator import GenericAllocator as GA
    st = GA.init(64)
    buf = jnp.arange(64, dtype=jnp.int32)
    st, p = GA.malloc(st, 8)
    st, buf = poison_free(GA, st, buf, p)
    assert int(buf[int(p)]) == int(np.int32(POISON))
    stale = jax.lax.dynamic_slice(buf, (p,), (8,))
    q = RpcQueue.create(8, 4, 64, sanitize=True)
    q = q.enqueue("san.rec", stale)           # BUG: freed bytes in payload
    q.flush()
    assert sanitize_stats()["poison_hits"] >= 1
    # the same program with a LIVE block is silent
    reset_sanitize_stats()
    st2, p2 = GA.malloc(st, 8)
    live = jax.lax.dynamic_slice(buf, (p2,), (8,))
    q2 = RpcQueue.create(8, 4, 64, sanitize=True)
    q2.enqueue("san.rec", jnp.zeros_like(live)).flush()
    assert sanitize_stats()["poison_hits"] == 0


def test_uaf_marshal_counter_on_freed_arena_ref():
    from repro.core.allocator import GenericAllocator as GA
    st = GA.init(64)
    arena = jnp.zeros((64,), jnp.int32)
    st, p = GA.malloc(st, 8)
    st = GA.free(st, p)
    before = sanitize_stats()["uaf_marshals"]
    rpc_call("san.probe", ArenaRef(arena, p, st, access=READ),
             result_shape=I32)
    assert sanitize_stats()["uaf_marshals"] == before + 1


def test_stale_ticket_read_counter():
    q = RpcQueue.create(8, 4, 64, reply_capacity=8, sanitize=True)
    q, t = q.enqueue_ticketed("san.echo", jnp.int32(5), returns=I32)
    q = q.flush()
    (val, ok), = q.results_host([int(t)], I32)
    assert ok and int(val) == 5
    q = q.enqueue("san.rec", jnp.int32(0))
    q = q.flush()                              # window slides
    before = sanitize_stats()["stale_ticket_reads"]
    (_v, ok2), = q.results_host([int(t)], I32)   # BUG: epoch-0 ticket
    assert not ok2
    assert sanitize_stats()["stale_ticket_reads"] == before + 1


def test_expand_sanitize_bit_identical_on_hazard_free_program():
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("d",))

    def region(x):
        q = team_queue()
        q = q.enqueue("san.rec", x * 2)
        q = q.enqueue("san.rec", jnp.float32(0.5))
        set_team_queue(q)
        return jnp.cumsum(x) + 1

    def run(sanitize):
        RECS.clear()
        f = expand(region, mesh, (P("d"),), P("d"), queue=True,
                   sanitize=sanitize)
        sq = ShardedRpcQueue.create(1, 8, 4, 64)
        sq2, out = f(sq, jnp.arange(4, dtype=jnp.int32))
        sq2.flush()
        return np.asarray(out), list(RECS)

    out_plain, recs_plain = run(False)
    reset_sanitize_stats()
    out_san, recs_san = run(True)
    np.testing.assert_array_equal(out_san, out_plain)
    assert recs_san == recs_plain and len(recs_plain) == 2
    st = sanitize_stats()
    assert st["canary_stomps"] == 0 and st["poison_hits"] == 0
    assert len(st["epochs"]) == 1 and st["epochs"][0]["sharded"]


def test_plain_queue_records_no_epochs():
    q = RpcQueue.create(8, 4, 64)
    q = q.enqueue("san.rec", jnp.arange(3))
    q.flush()
    assert sanitize_stats()["epochs"] == []
