"""End-to-end behaviour tests for the GPU First system: the examples run,
the data pipeline feeds the device loop by RPC, and the whole-program
execution model holds together."""
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run_example(name, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", name)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_example_gpu_first_port():
    out = _run_example("gpu_first_port.py")
    assert "verdict from GPU First measurement" in out
    assert "RPC wrote 2048 results" in out


def test_example_serve_demo():
    out = _run_example("serve_demo.py")
    assert "verified vs reference decode" in out


def test_example_train_100m_with_restart():
    out = _run_example("train_100m.py")
    assert "loss descended across a simulated failure/restart" in out


def test_host_rpc_data_pipeline_feeds_device_loop():
    """The paper's fscanf-by-RPC, for tokens: a host iterator feeds batches
    into a jitted loop through an ordered callback with prefetch."""
    from repro.core.device_main import device_run
    from repro.data.pipeline import make_host_pipeline

    def gen():
        i = 0
        while True:
            yield {"x": np.full((4,), float(i), np.float32)}
            i += 1

    fetch = make_host_pipeline(
        gen(), {"x": jax.ShapeDtypeStruct((4,), jnp.float32)}, prefetch=2)

    def step(i, acc):
        batch = fetch(i)
        return acc + batch["x"].sum()

    final = device_run(step, jnp.float32(0.0), 5, donate=False)
    # batches 0..4, each sums to 4*i
    assert float(final) == sum(4.0 * i for i in range(5))
    fetch.stop()


def test_synthetic_stream_deterministic():
    from repro.data.pipeline import SyntheticLM
    src = SyntheticLM(vocab_size=128, seq_len=16, batch=2)
    from repro.core.libc import rand_init
    s = rand_init(0)
    _, b1 = src.batch_at(s, jnp.int32(3))
    _, b2 = src.batch_at(s, jnp.int32(3))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    _, b3 = src.batch_at(s, jnp.int32(4))
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert int(b1["tokens"].max()) < 128
