"""Sharded device runtime (ISSUE 3): per-device heaps + per-device RPC
queues under ``expand``.

In-process tests drive the sharded state as a *logical* device axis (vmap on
one physical device — the sharded heap/queue are data layouts, not
placements); subprocess tests force a real multi-device host platform and
run the same machinery under ``shard_map`` (the pattern of
``test_multidevice.py``).

Property tests (satellite): per-device non-overlap, team-local watermark
monotonicity, sharded ``find_obj`` agreeing with the per-shard linear
reference; determinism: sharded-queue flush replay order is stable across
runs.
"""
import os
import random
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro.core.allocator import (
    FAIL, BalancedAllocator as BA, GenericAllocator as GA, ShardedAllocator
    as SA, ShardedHeap, find_obj, find_obj_linear, shard_heap)
from repro.core.rpc import (
    READ, REGISTRY, ArenaRef, RpcQueue, ShardedRpcQueue, flush_stats,
    reset_rpc_stats, rpc_call)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

I32S = jax.ShapeDtypeStruct((), jnp.int32)


def run_child(code: str, devices: int = 4, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    # pin the cpu platform: forced host devices ARE cpu devices, and letting
    # the child probe for accelerators stalls for minutes on hosts that
    # carry a (here unusable) TPU runtime
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# ---------------------------------------------------------------------------
# Sharded heap: property tests (per-device invariants)
# ---------------------------------------------------------------------------

D, SPAN, CAP = 4, 128, 16


def _drive_sharded(seed: int):
    """Random per-device op rounds against a ShardedHeap(Generic inner);
    mirrors each device's live set in python.  Returns (heap, live[d])."""
    rng = random.Random(seed)
    sh = shard_heap(GA.init(SPAN, cap=CAP), D)
    live = [dict() for _ in range(D)]      # global ptr -> size, per device
    for _ in range(12):
        if rng.random() < 0.6:
            sizes = [rng.randint(1, 24) for _ in range(D)]
            sh, ptrs = SA.malloc(sh, jnp.asarray(sizes, jnp.int32))
            for d, (p, s) in enumerate(zip(np.asarray(ptrs), sizes)):
                if p >= 0:
                    assert int(p) not in live[d]
                    live[d][int(p)] = s
        else:
            victims = []
            for d in range(D):
                if live[d] and rng.random() < 0.8:
                    v = rng.choice(sorted(live[d]))
                    del live[d][v]
                    victims.append(v)
                else:
                    victims.append(int(FAIL))
            sh = SA.free(sh, jnp.asarray(victims, jnp.int32)[:, None])
    return sh, live


@pytest.mark.parametrize("seed", range(5))
def test_sharded_heap_per_device_nonoverlap(seed):
    """No two live blocks overlap; every block stays inside its device's
    span (global pointer spaces are disjoint by construction)."""
    sh, live = _drive_sharded(seed)
    for d in range(D):
        blocks = sorted((p, s) for p, s in live[d].items())
        for p, s in blocks:
            assert d * SPAN <= p and p + s <= (d + 1) * SPAN
        for (p1, s1), (p2, _) in zip(blocks, blocks[1:]):
            assert p1 + s1 <= p2, f"dev {d}: overlap at {p1}+{s1} > {p2}"


@pytest.mark.parametrize("seed", range(5))
def test_sharded_heap_watermark_monotone(seed):
    """Each shard's watermark never lies below the end of any of its live
    blocks (team-local monotonicity)."""
    sh, live = _drive_sharded(seed)
    wm = np.asarray(sh.shards.watermark)
    for d in range(D):
        top = max((p - d * SPAN + s for p, s in live[d].items()), default=0)
        assert int(wm[d]) >= top


@pytest.mark.parametrize("seed", range(5))
def test_sharded_find_obj_matches_linear(seed):
    """Sharded find_obj == per-shard linear reference on live interiors,
    boundaries, freed, FAIL, and out-of-mesh probes."""
    sh, live = _drive_sharded(seed)
    probes = [int(FAIL), -7, D * SPAN, D * SPAN + 3]
    for d in range(D):
        probes += [d * SPAN, (d + 1) * SPAN - 1]
        for p, s in live[d].items():
            probes += [p, p + s - 1, p + s]
    for ptr in probes:
        f2, b2, s2 = (int(x) for x in find_obj(sh, jnp.int32(ptr)))
        fl, bl, sl = (int(x) for x in find_obj_linear(sh, jnp.int32(ptr)))
        assert f2 == fl, (ptr, f2, fl)
        if f2:
            assert (b2, s2) == (bl, sl), (ptr, b2, s2, bl, sl)
            d = ptr // SPAN
            assert b2 in live[d] and live[d][b2] == s2
            assert b2 <= ptr < b2 + s2


def test_sharded_heap_one_device_bit_identical():
    """A 1-device sharded heap is the single heap: identical pointer
    streams from the same request sequence (the acceptance contrast)."""
    single = GA.init(SPAN, cap=CAP)
    sh = shard_heap(GA.init(SPAN, cap=CAP), 1)
    for sizes in ([5, 9, 3], [2, 7], [1]):
        for s in sizes:
            single, p1 = GA.malloc(single, s)
            sh, p2 = SA.malloc(sh, jnp.asarray([s], jnp.int32))
            assert int(p1) == int(np.asarray(p2)[0])
    # balanced grid path
    bsing = BA.init(256, 2, 2, cap=16)
    bsh = shard_heap(BA.init(256, 2, 2, cap=16), 1)
    sizes = jnp.arange(1, 9, dtype=jnp.int32).reshape(2, 4)
    bsing, g1 = BA.malloc_grid(bsing, 2, 4, sizes)
    bsh, g2 = SA.malloc_grid(bsh, 2, 4, sizes[None])
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2)[0])


def test_arena_ref_marshals_sharded_global_ptr():
    """ArenaRef(ptr into shard d) ships the GLOBAL (base, size) — the RPC
    layer's _FindObj path works unchanged on sharded heaps."""
    sh = shard_heap(GA.init(SPAN, cap=CAP), 2)
    sh, ptrs = SA.malloc(sh, jnp.asarray([8, 12], jnp.int32))
    gp = int(np.asarray(ptrs)[1])          # device 1's block
    seen = {}
    REGISTRY.register(
        "shard.probe",
        lambda ptr, base, size, found, arena: seen.update(
            ptr=int(ptr), base=int(base), size=int(size), found=int(found))
        or np.int32(0))

    @jax.jit
    def prog(state, arena, ptr):
        r, _ = rpc_call("shard.probe", ArenaRef(arena, ptr, state,
                                                access=READ),
                        result_shape=I32S)
        return r

    prog(sh, jnp.zeros(2 * SPAN, jnp.float32), jnp.int32(gp + 5))
    jax.effects_barrier()
    assert seen == {"ptr": gp + 5, "base": gp, "size": 12, "found": 1}


# ---------------------------------------------------------------------------
# Sharded queue: (device, slot) replay order, determinism, drop accounting
# ---------------------------------------------------------------------------

def _fill_sharded_queue(n_dev=3, per_dev=3, cap=8):
    REGISTRY.register("shq.rec", _REC.append)
    q = ShardedRpcQueue.create(n_dev, cap, width=2)

    def fill(lq, dev):
        def body(i, lq):
            return lq.enqueue("shq.rec", dev * 100 + i)
        return lax.fori_loop(0, per_dev, body, lq)

    return ShardedRpcQueue(jax.vmap(fill)(q.q, jnp.arange(n_dev)))


_REC = []


def test_sharded_flush_replays_device_slot_order():
    _REC.clear()
    q = _fill_sharded_queue()
    q = q.flush()                          # concrete shards -> direct drain
    expect = [d * 100 + i for d in range(3) for i in range(3)]
    assert _REC == expect
    assert np.asarray(q.q.head).tolist() == [0, 0, 0]


def test_sharded_flush_deterministic_across_runs():
    """Replay order is a deterministic total order: two identical runs
    produce identical record sequences (satellite determinism test)."""
    runs = []
    for _ in range(2):
        _REC.clear()
        _fill_sharded_queue(n_dev=4, per_dev=5).flush()
        runs.append(list(_REC))
    assert runs[0] == runs[1]
    assert len(runs[0]) == 20


def test_sharded_flush_traced_path_inside_jit():
    """Flush of a TRACED sharded queue (logical shards, one device) rides
    one ordered io_callback and preserves (device, slot) order."""
    _REC.clear()
    REGISTRY.register("shq.rec", _REC.append)

    @jax.jit
    def prog():
        q = ShardedRpcQueue.create(2, 4, width=2)

        def fill(lq, dev):
            def body(i, lq):
                return lq.enqueue("shq.rec", dev * 10 + i)
            return lax.fori_loop(0, 2, body, lq)

        q = ShardedRpcQueue(jax.vmap(fill)(q.q, jnp.arange(2)))
        q = q.flush()
        return q.q.head

    head = prog()
    jax.effects_barrier()
    assert np.asarray(head).tolist() == [0, 0]
    assert _REC == [0, 1, 10, 11]


def test_sharded_flush_reports_per_shard_drops():
    """capacity + k enqueues on a shard drop exactly k records (summed over
    shards) — reported via flush_stats, with the surviving records replayed
    in order."""
    reset_rpc_stats()
    _REC.clear()
    q = _fill_sharded_queue(n_dev=2, per_dev=6, cap=4)   # 2 over per shard
    q.flush()
    assert _REC == [100 * d + i for d in range(2) for i in range(2, 6)]
    st = flush_stats()
    assert st["flushes"] == 1 and st["last_drops"] == 4 and st["drops"] == 4


def test_sharded_payload_replay_and_determinism():
    """Payload-carrying records on a sharded queue: every shard's arrays
    resolve against ITS arena slice, replay is (device, slot) order, and
    two identical runs produce identical sequences."""
    REGISTRY.register("shq.pay", lambda i, a: _REC.append((i, a.tolist())))

    def one_run():
        _REC.clear()
        q = ShardedRpcQueue.create(3, 4, width=2, payload_capacity=32)

        def fill(lq, dev):
            def body(i, lq):
                return lq.enqueue(
                    "shq.pay", dev * 100 + i,
                    (dev * 10 + i) + jnp.arange(3, dtype=jnp.int32))
            return lax.fori_loop(0, 2, body, lq)

        q = ShardedRpcQueue(jax.vmap(fill)(q.q, jnp.arange(3)))
        q = q.flush()
        assert np.asarray(q.q.head).tolist() == [0, 0, 0]
        assert np.asarray(q.q.phead).tolist() == [0, 0, 0]
        return list(_REC)

    runs = [one_run(), one_run()]
    expect = [(d * 100 + i, [d * 10 + i, d * 10 + i + 1, d * 10 + i + 2])
              for d in range(3) for i in range(2)]
    assert runs[0] == expect
    assert runs[0] == runs[1]


def test_sharded_payload_traced_flush_inside_jit():
    """The traced (in-jit) sharded flush ships the stacked arenas through
    one ordered io_callback; payloads still reattach per shard."""
    _REC.clear()
    REGISTRY.register("shq.pay2", lambda i, a: _REC.append((i, a.tolist())))

    @jax.jit
    def prog():
        q = ShardedRpcQueue.create(2, 4, width=2, payload_capacity=8)

        def fill(lq, dev):
            return lq.enqueue("shq.pay2", dev,
                              jnp.full((2,), dev, jnp.float32) + 0.5)

        q = ShardedRpcQueue(jax.vmap(fill)(q.q, jnp.arange(2)))
        q = q.flush()
        return q.q.head

    prog()
    jax.effects_barrier()
    assert _REC == [(0, [0.5, 0.5]), (1, [1.5, 1.5])]


def test_sharded_payload_per_shard_arena_drops():
    """Arena overflow is per shard and atomic: a shard whose arena fills
    drops the overflowing record entirely; other shards are untouched;
    drops sum across shards in flush_stats."""
    reset_rpc_stats()
    _REC.clear()
    REGISTRY.register("shq.pay3", lambda i, a: _REC.append((i, a.tolist())))

    q = ShardedRpcQueue.create(2, 8, width=2, payload_capacity=4)

    def fill(lq, dev):
        # 3-word payloads against a 4-word arena: per shard the first fits,
        # the second would need 6 > 4 and is dropped atomically
        def body(i, lq):
            return lq.enqueue("shq.pay3", dev * 10 + i,
                              jnp.full((3,), dev * 10 + i, jnp.int32))
        return lax.fori_loop(0, 2, body, lq)

    q = ShardedRpcQueue(jax.vmap(fill)(q.q, jnp.arange(2)))
    with pytest.warns(RuntimeWarning, match="payload"):
        q = q.flush()
    assert _REC == [(0, [0, 0, 0]), (10, [10, 10, 10])]
    st = flush_stats()
    assert st["arena_drops"] == 2 and st["last_arena_drops"] == 2
    assert st["drops"] == 0


def test_sharded_grid_flat_dispatch_matches_per_device():
    """The flattened D*NC-chunk malloc_grid/free_grid (the ISSUE-4 perf
    fix) is bit-identical to running each device's balanced grid op
    separately."""
    D, T, G = 4, 8, 4
    sizes = (jnp.arange(D * T * G, dtype=jnp.int32) % 7 + 1
             ).reshape(D, T, G)
    sh = shard_heap(BA.init(4096, 4, 2, cap=64), D)
    sh2, gptrs = SA.malloc_grid(sh, T, G, sizes)
    # reference: each device's shard through the plain balanced allocator
    for d in range(D):
        st = BA.init(4096, 4, 2, cap=64)
        st, ref = BA.malloc_grid(st, T, G, sizes[d])
        ref = np.asarray(ref)
        got = np.asarray(gptrs[d])
        expect = np.where(ref < 0, ref, d * sh.span + ref)
        np.testing.assert_array_equal(got, expect)
    # free half the grid, then the rest: per-shard watermarks return to 0
    half = jnp.where(jnp.arange(T)[None, :, None] % 2 == 0, gptrs,
                     jnp.int32(FAIL))
    rest = jnp.where(jnp.arange(T)[None, :, None] % 2 == 0, jnp.int32(FAIL),
                     gptrs)
    sh2 = SA.free_grid(sh2, T, G, half)
    sh2 = SA.free_grid(sh2, T, G, rest)
    assert (np.asarray(sh2.shards.watermark) == 0).all()


def test_place_sharded_state_single_device():
    """distributed.sharding helpers: the device-axis spec covers every mesh
    axis, and placement keeps values intact (1-device mesh in-process; the
    real-mesh path is exercised implicitly by expand's P(axes) in_specs)."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import (device_axis_spec,
                                            place_sharded_state)
    mesh = jax.make_mesh((1,), ("dev",))
    assert device_axis_spec(mesh) == P(("dev",))
    q = ShardedRpcQueue.create(1, 8, width=2)
    q2 = place_sharded_state(q, mesh)
    assert isinstance(q2, ShardedRpcQueue)
    np.testing.assert_array_equal(np.asarray(q2.q.callee),
                                  np.asarray(q.q.callee))


# ---------------------------------------------------------------------------
# Transport v4: sharded reply arena
# ---------------------------------------------------------------------------

def test_sharded_remote_malloc_reply_roundtrip():
    """ISSUE 5 acceptance (2-device sharded queue): each device's
    remote-malloc ticket reads back global (device, offset) pointers
    through ITS reply arena in deterministic (flush-order, device, slot)
    order; the pointers pass find_obj and marshal as ArenaRefs."""
    from repro.core.libc import (remote_heap_register, remote_malloc_enqueue,
                                 remote_malloc_results)
    remote_heap_register("heap.sh_rt", shard_heap(GA.init(SPAN, cap=CAP), 2))

    def one_run():
        sq = ShardedRpcQueue.create(2, 8, width=3, payload_capacity=16,
                                    reply_capacity=8)

        def fill(lq, dev):
            # each device asks the host heap's shard `dev` for two blocks
            lq, t = remote_malloc_enqueue(
                lq, "heap.sh_rt", (dev + 1) * jnp.asarray([8, 4], jnp.int32),
                device=dev)
            return lq, t

        qq, tks = jax.vmap(fill)(sq.q, jnp.arange(2))
        sq = ShardedRpcQueue(qq).flush()    # concrete: host-side drain
        return [np.asarray(sq.result(d, tks[d], (2,), jnp.int32)).tolist()
                for d in range(2)]

    run1 = one_run()
    # device d's pointers live in device d's span of the global encoding
    assert run1[0] == [0, 8]                        # dev 0: sizes 8, 4
    assert run1[1] == [SPAN, SPAN + 16]             # dev 1: sizes 16, 8
    state, _ = remote_malloc_results("heap.sh_rt")
    for d, ptrs in enumerate(run1):
        for p, size in zip(ptrs, [(d + 1) * 8, (d + 1) * 4]):
            fo, b, s = find_obj(state, jnp.int32(p))
            assert (int(fo), int(b), int(s)) == (1, p, size)

    # ...and the reply pointer marshals as an ArenaRef in a subsequent RPC
    seen = {}
    REGISTRY.register(
        "sh_rt.probe",
        lambda ptr, base, size, found, arena: seen.update(
            ptr=int(ptr), base=int(base), size=int(size), found=int(found))
        or np.int32(0))

    @jax.jit
    def probe(state, arena, ptr):
        r, _ = rpc_call("sh_rt.probe",
                        ArenaRef(arena, ptr, state, access=READ),
                        result_shape=I32S)
        return r

    probe(state, jnp.zeros(2 * SPAN, jnp.float32), jnp.int32(run1[1][0] + 3))
    jax.effects_barrier()
    assert seen == {"ptr": SPAN + 3, "base": SPAN, "size": 16, "found": 1}

    # deterministic replay: a second identical run on a fresh heap yields
    # the identical pointer streams
    remote_heap_register("heap.sh_rt", shard_heap(GA.init(SPAN, cap=CAP), 2))
    assert one_run() == run1


def test_sharded_reply_traced_flush_inside_jit():
    """The traced (in-jit) sharded two-phase flush ships stacked reply
    buffers back through the one ordered io_callback; each shard's tickets
    resolve against its own reply slice."""
    REGISTRY.register("shq.rep", lambda x: np.arange(int(x), int(x) + 2,
                                                     dtype=np.int32))

    @jax.jit
    def prog():
        q = ShardedRpcQueue.create(2, 4, width=2, reply_capacity=8)

        def fill(lq, dev):
            return lq.enqueue_ticketed(
                "shq.rep", dev * 10,
                returns=jax.ShapeDtypeStruct((2,), jnp.int32))

        qq, tks = jax.vmap(fill)(q.q, jnp.arange(2))
        q = ShardedRpcQueue(qq).flush()
        return q.result(0, tks[0], (2,), jnp.int32), \
            q.result(1, tks[1], (2,), jnp.int32)

    r0, r1 = prog()
    jax.effects_barrier()
    assert np.asarray(r0).tolist() == [0, 1]
    assert np.asarray(r1).tolist() == [10, 11]


def test_device_run_mesh_thread_queue_replies():
    """device_run(mesh=, thread_queue=, return_queue=): each device's step
    enqueues a ticketed RPC into its shard; the boundary flush returns the
    sharded queue with per-device reply tables the host can read."""
    out = run_child(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core.device_main import device_run
from repro.core.expand import team_id
from repro.core.rpc import REGISTRY

mesh = jax.make_mesh((2,), ("dev",))
REGISTRY.register("mesh.sq", lambda x: np.int32(x) * np.int32(x))

def step(i, s, lq):
    lq, t = lq.enqueue_ticketed("mesh.sq",
                                (s[0] + team_id()).astype(jnp.int32),
                                returns=jax.ShapeDtypeStruct((), jnp.int32))
    return s + 1.0, lq

final, q = device_run(step, jnp.zeros((1,), jnp.float32), 3, mesh=mesh,
                      thread_queue=True, return_queue=True, queue_reply=16)
assert float(final[0]) == 3.0
# step i on device d enqueued (i + d)^2; tickets are the epoch order 0..2
got = [[int(q.result(d, t)) for t in range(3)] for d in range(2)]
assert got == [[0, 1, 4], [1, 4, 9]], got
print("MESH_REPLY_OK")
""", devices=2)
    assert "MESH_REPLY_OK" in out


# ---------------------------------------------------------------------------
# Sharded paged KV cache (serving conversion)
# ---------------------------------------------------------------------------

def _kv_cfg():
    from repro.configs import CONFIGS
    import dataclasses as dc
    cfg = CONFIGS["llama3.2-3b"].reduced()
    return dc.replace(cfg, num_layers=1)


def test_kvcache_sharded_one_device_bit_identical():
    """mesh=1 sharded page heap == single heap: identical page tables and
    lengths through alloc/advance/release cycles."""
    from repro.serving import kvcache
    cfg = _kv_cfg()
    kv1 = kvcache.paged_cache_init(cfg, 4, 64, page_size=16)
    kv2 = kvcache.paged_cache_init(cfg, 4, 64, page_size=16, mesh=1)
    active = jnp.asarray([True, True, False, True])
    for _ in range(20):
        kv1 = kvcache.advance(kvcache.ensure_pages(kv1, active), active)
        kv2 = kvcache.advance(kvcache.ensure_pages(kv2, active), active)
    np.testing.assert_array_equal(np.asarray(kv1.page_table),
                                  np.asarray(kv2.page_table))
    np.testing.assert_array_equal(np.asarray(kv1.lengths),
                                  np.asarray(kv2.lengths))
    mask = jnp.asarray([True, False, False, True])
    kv1 = kvcache.release_slots(kv1, mask)
    kv2 = kvcache.release_slots(kv2, mask)
    np.testing.assert_array_equal(np.asarray(kv1.page_table),
                                  np.asarray(kv2.page_table))


def test_kvcache_sharded_two_devices():
    """Under 2 heap shards, each slot block draws page ids from its own
    device's span; release + realloc recycles within the span."""
    from repro.serving import kvcache
    cfg = _kv_cfg()
    B, D = 4, 2
    kv = kvcache.paged_cache_init(cfg, B, 64, page_size=16, mesh=D)
    span = kv.alloc.span
    active = jnp.ones((B,), bool)
    for _ in range(32):
        kv = kvcache.advance(kvcache.ensure_pages(kv, active), active)
    table = np.asarray(kv.page_table)
    used = np.asarray(kv.lengths) // 16      # pages allocated per slot
    for b in range(B):
        dev = b // (B // D)
        pages = table[b, :used[b]]
        assert ((pages >= dev * span) & (pages < (dev + 1) * span)).all(), \
            (b, dev, pages)
    # all in-use pages globally distinct
    live = [int(p) for b in range(B) for p in table[b, :used[b]]]
    assert len(live) == len(set(live))
    kv = kvcache.release_slots(kv, jnp.asarray([True, False, True, False]))
    assert int(kv.lengths[0]) == 0 and int(kv.lengths[1]) == 32


# ---------------------------------------------------------------------------
# Real-mesh subprocess tests: expand threading, device_run(mesh=), ragged
# ---------------------------------------------------------------------------

def test_expand_team_heap_and_queue_over_mesh():
    """Per-team malloc inside an expanded region; team_ptr globals resolve
    through find_obj after the region; sharded ring flush replays (device,
    slot) — and the replay is identical across two runs."""
    out = run_child(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.allocator import GenericAllocator as GA, shard_heap, find_obj
from repro.core.expand import (expand, set_team_heap, set_team_queue,
                               team_heap, team_id, team_ptr, team_queue)
from repro.core.libc import LogRing, drain_log_lines

mesh = jax.make_mesh((2, 2), ("data", "model"))

def region():
    st = team_heap()
    st, p = GA.malloc(st, 8 + team_id())
    set_team_heap(st)
    set_team_queue(team_queue().log(team_id(), p.astype(jnp.float32)))
    return team_ptr(p)[None]

f = expand(region, mesh, in_specs=(), out_specs=P(("data", "model")),
           heap=True, queue=True)

def once():
    heap = shard_heap(GA.init(64, cap=8), 4)
    ring = LogRing.create_sharded(4, 16)
    heap2, ring2, gptrs = jax.jit(f)(heap, ring)
    drain_log_lines()
    ring2.flush()
    return jax.device_get(heap2), np.asarray(gptrs), drain_log_lines()

heap2, gptrs, recs1 = once()
assert sorted(gptrs.tolist()) == [0, 64, 128, 192], gptrs
for d, gp in enumerate(gptrs):
    fo, b, s = find_obj(heap2, int(gp))
    assert int(fo) == 1 and int(b) == int(gp) and int(s) == 8 + d
_, _, recs2 = once()
assert recs1 == recs2 == [(d, 0.0) for d in range(4)], (recs1, recs2)
print("TEAM_HEAP_OK")
""")
    assert "TEAM_HEAP_OK" in out


def test_device_run_mesh_sharded_hook_queue():
    """device_run(mesh=): hooks ride per-device queue shards; every device
    reports its firings; records replay in (device, slot) order; zero host
    contact during the loop (all stats arrive via the ONE flush)."""
    out = run_child(r"""
import jax, jax.numpy as jnp
from repro.core.device_main import HostHook, device_run
from repro.core.expand import team_id
from repro.core.rpc import rpc_stats, reset_rpc_stats

mesh = jax.make_mesh((4,), ("dev",))
recs = []
hook = HostHook(every=3,
                extract=lambda i, s: s[0] + team_id().astype(jnp.float32),
                host_fn=lambda i, v: recs.append((i, v)),
                name="hook.mesh")
reset_rpc_stats()
final = device_run(lambda i, s: s + 1.0, jnp.zeros((2,), jnp.float32), 10,
                   hooks=[hook], mesh=mesh)
assert float(final[0]) == 10.0
expect = [(i, float(i + d)) for d in range(4) for i in (3, 6, 9)]
assert recs == expect, recs
assert rpc_stats("hook.mesh")["calls"] == 12
print("MESH_RUN_OK")
""")
    assert "MESH_RUN_OK" in out


def test_device_run_mesh_hook_array_payload():
    """device_run(mesh=): a hook whose extract returns an ARRAY leaf ships
    it through the per-device payload arenas — zero host contact in the
    loop, one gathered flush, (device, slot)-ordered vectors on the host."""
    out = run_child(r"""
import jax, jax.numpy as jnp
from repro.core.device_main import HostHook, device_run
from repro.core.expand import team_id

mesh = jax.make_mesh((2,), ("dev",))
recs = []
hook = HostHook(every=2,
                extract=lambda i, s: s + team_id().astype(jnp.float32),
                host_fn=lambda i, v: recs.append((i, v.tolist())),
                name="hook.mesh_payload")
final = device_run(lambda i, s: s + 1.0, jnp.zeros((3,), jnp.float32), 4,
                   hooks=[hook], mesh=mesh)
assert float(final[0]) == 4.0
expect = [(i, [float(i + d)] * 3) for d in range(2) for i in (2, 4)]
assert recs == expect, recs
print("MESH_PAYLOAD_OK")
""", devices=2)
    assert "MESH_PAYLOAD_OK" in out


def test_parallel_for_ragged_over_mesh():
    """n not divisible by mesh.size: padded + masked tail, equals serial."""
    out = run_child(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core.expand import parallel_for, serial_for

mesh = jax.make_mesh((2, 2), ("data", "model"))
arr = jnp.arange(64.0)
body = lambda i, a: a[i] * 3.0 + i
for n in (30, 7, 64, 61):
    pf = parallel_for(body, n, arr, mesh=mesh)
    sf = serial_for(body, n, arr)
    assert pf.shape == sf.shape, (n, pf.shape, sf.shape)
    np.testing.assert_allclose(np.asarray(pf), np.asarray(sf))
print("RAGGED_OK")
""")
    assert "RAGGED_OK" in out
