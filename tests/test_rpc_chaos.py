"""Chaos suite for the fault-tolerant host boundary (ISSUE 9).

Seeded :class:`repro.testing.faults.FaultPlan`s drive the drain of all
three transports — per-enqueue "immediate" flushes, one batched flush,
2-shard sharded — and every leg must agree bit-for-bit on statuses and
host effects.  The CI ``chaos`` job widens the seed matrix via
``RPC_FAULT_SEEDS`` (comma-separated ints); the tier-1 default keeps a
small fixed set so the suite always runs.

The v6 async transports join the matrix: the same seeded plans must
produce bit-identical STATUSES on the batched sync drain, the double-
buffered async drain, and the sharded-async drain (occurrence indices
are reserved in canonical ``(device, slot)`` order at submit time, so
background-thread scheduling cannot reshuffle fault addressing).  Host
effects stay order-identical on the single async queue — one FIFO
executor per (slot, device) — and multiset-identical on the sharded-
async one, whose cross-shard interleaving is deliberately unspecified.

Also home to the satellite fixes' unit coverage: the drain-side error
log (`error_log()`, ``flush_stats()['callee_errors']``), the
once-per-queue failed-ticket-read warning, and the ``sanitize=True``
``failed_ticket_reads`` counter.
"""
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rpc
from repro.core.rpc import (REGISTRY, RetryPolicy, RpcQueue,
                            ShardedRpcQueue, STATUS_CALLEE_RAISED,
                            STATUS_DROPPED, STATUS_OK, STATUS_TIMEOUT,
                            flush_stats, reset_rpc_stats)
from repro.testing.faults import Fault, FaultPlan

# the conformance runners + record set live next to the reference model
from test_rpc_differential import (_CONFORMANCE_RECORDS, _SEEN, CAP, PC, RC,
                                   WIDTH, _dev_enqueue, _payload_for,
                                   _run_batched, _run_immediate,
                                   _run_sharded)

_I32 = jax.ShapeDtypeStruct((), jnp.int32)

FAULT_SEEDS = [int(s) for s in
               os.environ.get("RPC_FAULT_SEEDS", "0,1,2,3").split(",") if s]


def _echo(x):
    return np.int32(x)


REGISTRY.register("chaos.echo", _echo)
REGISTRY.register("chaos.echo_idem", _echo, idempotent=True)


# ---------------------------------------------------------------------------
# Seeded cross-transport chaos matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", FAULT_SEEDS)
@pytest.mark.parametrize("retry", [False, True])
def test_chaos_seeded_transport_conformance(seed, retry):
    """Same seeded fault plan, three transports: statuses and host
    effects must be bit-identical, and the flush must COMPLETE on every
    leg (no escaped exception, every ticket resolvable)."""
    base = FaultPlan.generate(seed, ["diff.int", "diff.float"],
                              n_faults=3, max_index=6)
    legs = []
    for runner in (_run_immediate, _run_batched, _run_sharded):
        reset_rpc_stats()
        legs.append(runner(_CONFORMANCE_RECORDS,
                           FaultPlan(base.faults), retry))
    (st_a, fx_a), (st_b, fx_b), (st_c, fx_c) = legs
    assert st_a == st_b == st_c
    assert fx_a == fx_b == fx_c
    assert len(st_a) == len(_CONFORMANCE_RECORDS)


@pytest.mark.parametrize("seed", FAULT_SEEDS)
def test_chaos_callee_raises_first_attempt(seed):
    """The acceptance scenario, seed-positioned: callee N (the seed picks
    which occurrence) raises on its FIRST attempt.  The flush completes
    on all three transports, survivors replay in order, the victim
    reports CALLEE_RAISED without retry and OK after one retry for the
    idempotent callee — bit-identical across transports."""
    n_int = sum(1 for k, *_ in _CONFORMANCE_RECORDS if k == "i")
    occ = seed % n_int
    victim = Fault("raise", "diff.int", occ)
    for retry in (False, True):
        legs = []
        for runner in (_run_immediate, _run_batched, _run_sharded):
            reset_rpc_stats()
            legs.append(runner(_CONFORMANCE_RECORDS,
                               FaultPlan([victim]), retry))
        (st_a, fx_a), (st_b, fx_b), (st_c, fx_c) = legs
        assert st_a == st_b == st_c
        assert fx_a == fx_b == fx_c
        # the victim is the occ-th diff.int record; everything else OK
        idx = [i for i, (k, *_r) in enumerate(_CONFORMANCE_RECORDS)
               if k == "i"][occ]
        want = STATUS_OK if retry else STATUS_CALLEE_RAISED
        assert st_a[idx] == want
        assert all(s == STATUS_OK for i, s in enumerate(st_a) if i != idx)
        n_effects = len(_CONFORMANCE_RECORDS) - (0 if retry else 1)
        assert len(fx_a) == n_effects


# ---------------------------------------------------------------------------
# v6 async legs: the same seeded plans on the double-buffered transports
# ---------------------------------------------------------------------------

def _run_async(records, plan, retry):
    """Transport (d): v6 double-buffered queue — one flush submits the
    epoch, a second collects it, ``join()`` settles the background drain.
    ``carry_budget`` stays 0 so the status lane is comparable record for
    record with the synchronous legs."""
    _SEEN.clear()
    q = RpcQueue.create(max(CAP, len(records)), width=WIDTH,
                        payload_capacity=4 * PC, reply_capacity=4 * RC,
                        mode="async",
                        retry=RetryPolicy(max_attempts=2) if retry else None)
    tix = []
    for kind, tag, plen, nrep in records:
        payload = _payload_for(kind, plen, tag)
        q, t = _dev_enqueue(q, kind, tag, nrep, payload, None)
        tix.append(t)
    # the injector must stay installed until the BACKGROUND drain is done
    # (it consults the process-wide injector at drain time, not submit)
    rpc.set_fault_injector(plan)
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            q = q.flush()                  # submit
            q = q.flush()                  # collect
        assert q.join()
        jax.effects_barrier()
    finally:
        rpc.set_fault_injector(None)
    return q.statuses_host(tix), list(_SEEN)


def _run_sharded_async(records, plan, retry, D=2):
    """Transport (e): 2-shard sharded-async queue — per-device epochs on
    independent executors, block-distributed records so the canonical
    ``(device, slot)`` reservation order equals the batched order."""
    _SEEN.clear()
    sq = ShardedRpcQueue.create(D, max(CAP, len(records)), width=WIDTH,
                                payload_capacity=4 * PC,
                                reply_capacity=4 * RC, mode="async",
                                retry=RetryPolicy(max_attempts=2)
                                if retry else None)
    per = -(-len(records) // D)
    locals_ = [sq.local(d) for d in range(D)]
    tix = []
    for i, (kind, tag, plen, nrep) in enumerate(records):
        d = i // per
        payload = _payload_for(kind, plen, tag)
        locals_[d], t = _dev_enqueue(locals_[d], kind, tag, nrep,
                                     payload, None)
        tix.append((d, t))
    stacked = ShardedRpcQueue(
        jax.tree.map(lambda *xs: jnp.stack(xs), *locals_))
    rpc.set_fault_injector(plan)
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            stacked = stacked.flush()      # submit per device
            stacked = stacked.flush()      # collect per device
        assert stacked.join()
        jax.effects_barrier()
    finally:
        rpc.set_fault_injector(None)
    return [int(stacked.result_status(d, t)) for d, t in tix], list(_SEEN)


@pytest.mark.parametrize("seed", FAULT_SEEDS)
@pytest.mark.parametrize("retry", [False, True])
def test_chaos_async_transport_conformance(seed, retry):
    """One seeded fault plan, three drains: batched sync, async,
    sharded-async — statuses must be bit-identical.  Reply arenas are
    sized so no record overflows: the async submit RESERVES occurrence
    indices for every surviving record while a sync drain skips records
    it atomically drops at reply overflow, so overflow would
    legitimately diverge fault addressing between the legs."""
    base = FaultPlan.generate(seed, ["diff.int", "diff.float"],
                              n_faults=3, max_index=6)
    legs = []
    for runner in (_run_batched, _run_async, _run_sharded_async):
        reset_rpc_stats()
        legs.append(runner(_CONFORMANCE_RECORDS, FaultPlan(base.faults),
                           retry))
    (st_b, fx_b), (st_a, fx_a), (st_s, fx_s) = legs
    assert st_b == st_a == st_s            # bit-identical statuses
    assert fx_b == fx_a                    # single async: FIFO executor
    # sharded-async: per-shard suborder is deterministic, the cross-shard
    # merge is not — compare as a multiset
    assert sorted(fx_b, key=repr) == sorted(fx_s, key=repr)


@pytest.mark.parametrize("retry", [False, True])
def test_chaos_async_callee_raise_conformance(retry):
    """The acceptance scenario on the async legs: diff.int occurrence 1
    raises on its first attempt — CALLEE_RAISED everywhere without
    retry, OK everywhere with one (idempotent-gated) retry."""
    victim = Fault("raise", "diff.int", 1)
    legs = []
    for runner in (_run_batched, _run_async, _run_sharded_async):
        reset_rpc_stats()
        legs.append(runner(_CONFORMANCE_RECORDS, FaultPlan([victim]),
                           retry))
    (st_b, fx_b), (st_a, fx_a), (st_s, _fx_s) = legs
    assert st_b == st_a == st_s
    want = STATUS_OK if retry else STATUS_CALLEE_RAISED
    assert st_a[1] == want                 # records: i11 [i12] f13 ...
    assert fx_b == fx_a


# ---------------------------------------------------------------------------
# Satellite 1: callee exceptions never escape io_callback; error_log()
# keeps the traceback; flush_stats() counts
# ---------------------------------------------------------------------------

def test_callee_exception_isolated_and_logged():
    REGISTRY.register("chaos.boom",
                      lambda x: (_ for _ in ()).throw(ValueError("bang")))
    reset_rpc_stats()
    rpc.clear_error_log()
    q = RpcQueue.create(8, 2, 32, reply_capacity=16)
    q, t_ok = q.enqueue_ticketed("chaos.echo", 5, returns=_I32)
    q, t_bad = q.enqueue_ticketed("chaos.boom", 1, returns=_I32)
    q, t_ok2 = q.enqueue_ticketed("chaos.echo", 7, returns=_I32)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        q = q.flush()                     # must NOT raise
        jax.effects_barrier()
    assert any("isolated" in str(x.message) for x in w)
    # siblings survive in order with live replies
    assert int(q.result(t_ok)) == 5 and int(q.result(t_ok2)) == 7
    assert int(q.result_status(t_bad)) == STATUS_CALLEE_RAISED
    v, ok = q.result_ok(t_bad, (), jnp.int32)
    assert not bool(ok) and int(v) == 0
    stats = flush_stats()
    assert stats["callee_errors"] == 1
    assert stats["last_callee_errors"] == 1
    log = rpc.error_log()
    assert log and log[-1]["callee"] == "chaos.boom"
    assert "bang" in log[-1]["traceback"]
    assert log[-1]["ticket"] == int(t_bad)


def test_timeout_marks_record_and_drain_survives():
    import time
    REGISTRY.register("chaos.hang",
                      lambda x: (time.sleep(0.6), np.int32(1))[1])
    reset_rpc_stats()
    q = RpcQueue.create(4, 1, 16, reply_capacity=8, timeout=0.05)
    q, t = q.enqueue_ticketed("chaos.hang", 1, returns=_I32)
    q, t2 = q.enqueue_ticketed("chaos.echo", 3, returns=_I32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        q = q.flush()
        jax.effects_barrier()
    assert int(q.result_status(t)) == STATUS_TIMEOUT
    assert int(q.result(t2)) == 3         # the sibling still replays
    assert flush_stats()["callee_errors"] == 1


def test_retry_redrives_idempotent_only():
    calls = {"idem": 0, "plain": 0}

    def flaky_idem(x):
        calls["idem"] += 1
        if calls["idem"] == 1:
            raise RuntimeError("transient")
        return np.int32(x + 1)

    def flaky_plain(x):
        calls["plain"] += 1
        raise RuntimeError("always")

    REGISTRY.register("chaos.flaky_idem", flaky_idem, idempotent=True)
    REGISTRY.register("chaos.flaky_plain", flaky_plain)
    reset_rpc_stats()
    q = RpcQueue.create(8, 2, 32, reply_capacity=16,
                        retry=RetryPolicy(max_attempts=3))
    q, ti = q.enqueue_ticketed("chaos.flaky_idem", 10, returns=_I32)
    q, tp = q.enqueue_ticketed("chaos.flaky_plain", 1, returns=_I32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        q = q.flush()
        jax.effects_barrier()
    assert int(q.result_status(ti)) == STATUS_OK    # redriven to success
    assert int(q.result(ti)) == 11
    assert calls["idem"] == 2
    assert int(q.result_status(tp)) == STATUS_CALLEE_RAISED
    assert calls["plain"] == 1                      # NOT retried
    assert flush_stats()["retries"] == 1


# ---------------------------------------------------------------------------
# Satellite 2: result() on a failed/dropped ticket warns once per queue;
# sanitize=True counts failed_ticket_reads
# ---------------------------------------------------------------------------

def test_failed_ticket_read_warns_once_per_queue():
    REGISTRY.register("chaos.boom2",
                      lambda x: (_ for _ in ()).throw(RuntimeError("x")))
    q = RpcQueue.create(4, 1, 16, reply_capacity=8)
    q, t = q.enqueue_ticketed("chaos.boom2", 1, returns=_I32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        q = q.flush()
        jax.effects_barrier()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        q.result(t)
        q.result(t)                       # second consult: no second warn
        relevant = [x for x in w
                    if "failed/dropped ticket" in str(x.message)]
    assert len(relevant) == 1
    assert str(int(t)) in str(relevant[0].message)


def test_sanitize_counts_failed_ticket_reads():
    REGISTRY.register("chaos.boom3",
                      lambda x: (_ for _ in ()).throw(RuntimeError("y")))
    rpc.reset_sanitize_stats()
    q = RpcQueue.create(4, 1, 16, reply_capacity=8, sanitize=True)
    q, t = q.enqueue_ticketed("chaos.boom3", 1, returns=_I32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        q = q.flush()
        jax.effects_barrier()
        q.result(t)
        q.result(t)
    assert rpc.sanitize_stats()["failed_ticket_reads"] == 2


def test_dropped_and_stale_statuses():
    q = RpcQueue.create(4, 1, 16, reply_capacity=8)
    q, t_drop = q.enqueue_ticketed("chaos.echo", 1, returns=_I32,
                                   where=jnp.bool_(False))
    q, t_live = q.enqueue_ticketed("chaos.echo", 2, returns=_I32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        q = q.flush()
        jax.effects_barrier()
    assert int(q.result_status(t_drop)) == STATUS_DROPPED
    assert int(q.result_status(t_live)) == STATUS_OK
    # a later flush slides the window: the old ticket reads STALE
    q = q.enqueue("chaos.echo", 3)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        q = q.flush()
        jax.effects_barrier()
    assert int(q.result_status(t_live)) == rpc.STATUS_STALE


def test_pressure_monotone_and_resets():
    q = RpcQueue.create(4, 1, 16, reply_capacity=8)
    assert float(q.pressure()) == 0.0
    p_last = 0.0
    for i in range(3):
        q, _ = q.enqueue_ticketed("chaos.echo", i, returns=_I32)
        p = float(q.pressure())
        assert p > p_last
        p_last = p
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        q = q.flush()
        jax.effects_barrier()
    assert float(q.pressure()) == 0.0


def test_error_log_caps_and_clears():
    REGISTRY.register("chaos.boom4",
                      lambda x: (_ for _ in ()).throw(RuntimeError("z")))
    rpc.clear_error_log()
    q = RpcQueue.create(8, 1, 32, reply_capacity=16)
    tix = []
    for i in range(3):
        q, t = q.enqueue_ticketed("chaos.boom4", i, returns=_I32)
        tix.append(t)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        q = q.flush()
        jax.effects_barrier()
    log = rpc.error_log()
    assert len(log) == 3
    assert [e["ticket"] for e in log] == [int(t) for t in tix]
    rpc.clear_error_log()
    assert rpc.error_log() == []
