import os
import sys

# single-device runtime for the test suite (the 512-device dry-run only ever
# runs via ``python -m repro.launch.dryrun`` or the subprocess tests)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

import pytest  # noqa: E402

# Under CPU async dispatch an ordered io_callback drain can DEADLOCK: the
# callback thread blocks in np.asarray on a large operand whose definition
# event is queued behind the computation the callback belongs to, while
# the test sits in block_until_ready.  Environment-dependent (kernel /
# thread-pool sizing) and reproducible on some containers; synchronous
# dispatch removes the race without changing any tested semantics.
# benchmarks/common.py carries the same pin for the bench processes, and
# ``RpcQueue.create`` warns (once per process) if it ever sees the flag
# live — rpc._check_cpu_async_dispatch — so a dropped pin surfaces as a
# RuntimeWarning at queue construction instead of a hung suite.
jax.config.update("jax_cpu_enable_async_dispatch", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
