import os
import sys

# single-device runtime for the test suite (the 512-device dry-run only ever
# runs via ``python -m repro.launch.dryrun`` or the subprocess tests)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
