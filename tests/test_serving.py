"""Serving engine: paged KV on the balanced allocator, continuous batching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS
from repro.models import build_model
from repro.serving import kvcache
from repro.serving.engine import ServingEngine


@pytest.fixture(scope="module")
def dense_model():
    cfg = CONFIGS["llama3.2-3b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _greedy_reference(model, params, prompt, max_new):
    cache, _ = model.init_cache(1, 128)
    for t in prompt[:-1]:
        _, cache = model.decode_step(params, cache,
                                     jnp.asarray([t], jnp.int32))
    out, cur = [], prompt[-1]
    for _ in range(max_new):
        lg, cache = model.decode_step(params, cache,
                                      jnp.asarray([cur], jnp.int32))
        cur = int(jnp.argmax(lg[0]))
        out.append(cur)
    return out


def test_engine_matches_reference_decode(dense_model):
    cfg, model, params = dense_model
    prompt = [5, 17, 42, 7]
    ref = _greedy_reference(model, params, prompt, 6)
    eng = ServingEngine(model, params, batch_slots=3, max_len=64, page_size=8)
    r1 = eng.submit(prompt, max_new=6)
    r2 = eng.submit([9, 3], max_new=4)
    res = eng.run_until_drained()
    assert res[r1] == ref
    assert len(res[r2]) == 4


def test_engine_slot_reuse_is_clean(dense_model):
    """A released slot must not leak KV into the next request (O(1) chunk
    reclaim must actually reset visibility)."""
    cfg, model, params = dense_model
    prompt = [11, 23, 4]
    ref = _greedy_reference(model, params, prompt, 5)
    eng = ServingEngine(model, params, batch_slots=1, max_len=64, page_size=8)
    a = eng.submit([7, 7, 7, 7, 7], max_new=3)     # dirties slot 0
    b = eng.submit(prompt, max_new=5)               # reuses slot 0
    res = eng.run_until_drained()
    assert res[b] == ref


def test_engine_spill_sink_receives_page_ids(dense_model):
    """Host-side page spill (transport v3): every retiring request ships
    its page-id list as ONE batched payload RPC before its slot is
    released — ids are the slot's live page-table prefix, distinct, and
    consistent with the request's token count."""
    cfg, model, params = dense_model
    spilled = []

    def sink(rid, n_tokens, pages):
        spilled.append((int(rid), int(n_tokens), pages.tolist()))

    eng = ServingEngine(model, params, batch_slots=2, max_len=64,
                        page_size=8, spill_sink=sink)
    r1 = eng.submit([5, 17, 42, 7], max_new=6)
    r2 = eng.submit([9, 3], max_new=13)
    res = eng.run_until_drained()
    assert len(res) == 2 and len(spilled) == 2
    by_rid = {rid: (n, pages) for rid, n, pages in spilled}
    assert set(by_rid) == {r1, r2}
    for rid, (n_tokens, pages) in by_rid.items():
        # one page per started page_size window, all ids distinct
        assert len(pages) == -(-n_tokens // 8)
        assert len(set(pages)) == len(pages)
    # cache holds prompt + generated - 1 tokens (the final sampled token is
    # harvested without ever being fed back)
    # r1: 4 prompt + 6 generated -> 9 written tokens -> 2 pages of 8
    assert by_rid[r1][0] == 9 and len(by_rid[r1][1]) == 2
    # r2: 2 prompt + 13 generated -> 14 written tokens -> 2 pages
    assert by_rid[r2][0] == 14 and len(by_rid[r2][1]) == 2
    # v4: every spill is ACKED through the reply arena — the sink returned
    # None, so the ack defaults to the page count it was handed
    assert eng.spill_acks == {rid: len(pages)
                              for rid, (_, pages) in by_rid.items()}


def test_engine_spill_ack_carries_sink_return(dense_model):
    """A spill sink that RETURNS a value sees that value come back as the
    ack (the reply arena round-trip through the engine's flush)."""
    cfg, model, params = dense_model

    def sink(rid, n_tokens, pages):
        return 1000 + int(rid)

    eng = ServingEngine(model, params, batch_slots=1, max_len=32,
                        page_size=8, spill_sink=sink)
    r1 = eng.submit([4, 2], max_new=3)
    eng.run_until_drained()
    assert eng.spill_acks == {r1: 1000 + r1}

    # a sink written against the pre-ack contract may return non-scalars
    # (here: the page list itself) — the flush must not crash, and the ack
    # is the drain's 1-word coercion (first element)
    spilled = []

    def page_sink(rid, n_tokens, pages):
        spilled.append(pages.tolist())
        return pages

    eng2 = ServingEngine(model, params, batch_slots=1, max_len=32,
                         page_size=8, spill_sink=page_sink)
    r2 = eng2.submit([4, 2], max_new=3)
    eng2.run_until_drained()
    assert spilled and eng2.spill_acks == {r2: spilled[0][0]}


def test_engine_spill_flaky_sink_is_retried(dense_model):
    """PR 9: a sink that fails its first delivery is re-enqueued in a
    fresh epoch (application-level retry, spill_retries rounds) — the
    ack still lands and nothing degrades."""
    cfg, model, params = dense_model
    calls = {}

    def flaky(rid, n_tokens, pages):
        calls[int(rid)] = calls.get(int(rid), 0) + 1
        if calls[int(rid)] == 1:
            raise RuntimeError("transient spill-store hiccup")
        return int(rid) + 500

    import warnings
    eng = ServingEngine(model, params, batch_slots=1, max_len=32,
                        page_size=8, spill_sink=flaky, spill_retries=2)
    r1 = eng.submit([4, 2], max_new=3)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        eng.run_until_drained()
    assert calls[r1] == 2
    assert eng.spill_acks == {r1: r1 + 500}
    assert eng.recompute_on_readmit == set()


def test_engine_spill_dead_sink_degrades_to_recompute(dense_model):
    """PR 9: a sink that fails EVERY attempt exhausts the retry budget —
    the engine records the failed ack as None, marks the request for
    recompute-on-readmit, and the tick completes (no wedge, no raise)."""
    cfg, model, params = dense_model

    def dead(rid, n_tokens, pages):
        raise RuntimeError("spill store down")

    import warnings
    eng = ServingEngine(model, params, batch_slots=1, max_len=32,
                        page_size=8, spill_sink=dead, spill_retries=1)
    r1 = eng.submit([4, 2], max_new=3)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        res = eng.run_until_drained()
    assert len(res[r1]) == 3               # decode itself unaffected
    assert eng.spill_acks == {r1: None}
    assert eng.recompute_on_readmit == {r1}


def test_engine_spill_disabled_by_default(dense_model):
    cfg, model, params = dense_model
    eng = ServingEngine(model, params, batch_slots=1, max_len=32,
                        page_size=8)
    assert eng.spill_q is None
    eng.submit([3, 1], max_new=2)
    eng.run_until_drained()        # no spill machinery touched


def test_engine_mixed_lengths_continuous_batching(dense_model):
    cfg, model, params = dense_model
    eng = ServingEngine(model, params, batch_slots=2, max_len=64, page_size=8)
    rids, refs = [], []
    for i, (prompt, n) in enumerate([([3, 1], 7), ([9, 9, 9, 2], 3),
                                     ([5], 5), ([8, 2, 4], 6)]):
        rids.append(eng.submit(prompt, max_new=n))
        refs.append(_greedy_reference(model, params, prompt, n))
    res = eng.run_until_drained()
    for rid, ref in zip(rids, refs):
        assert res[rid] == ref, rid


def test_engine_sharded_page_heap_matches(dense_model):
    """ISSUE 3: an engine on the sharded page heap (mesh=2: one heap shard
    per device block of slots) decodes exactly what the single-heap engine
    decodes — page ids move, token streams don't."""
    from repro.core.allocator import ShardedHeap
    cfg, model, params = dense_model
    prompts = [([5, 17, 42, 7], 6), ([9, 3], 4)]
    engines = [
        ServingEngine(model, params, batch_slots=2, max_len=64, page_size=8),
        ServingEngine(model, params, batch_slots=2, max_len=64, page_size=8,
                      mesh=2),
    ]
    assert isinstance(engines[1].kv.alloc, ShardedHeap)
    results = []
    for eng in engines:
        rids = [eng.submit(p, max_new=n) for p, n in prompts]
        res = eng.run_until_drained()
        results.append([res[r] for r in rids])
    assert results[0] == results[1]


def test_paged_cache_allocator_lifecycle(dense_model):
    cfg, _, _ = dense_model
    kv = kvcache.paged_cache_init(cfg, batch_slots=2, max_len=64, page_size=8)
    active = jnp.asarray([True, True])
    # first token allocates page 0 of each slot's chunk
    kv = kvcache.ensure_pages(kv, active)
    assert int(kv.alloc.count[0]) == 1 and int(kv.alloc.count[1]) == 1
    # advancing within a page allocates nothing
    kv = kvcache.advance(kv, active)
    kv = kvcache.ensure_pages(kv, active)
    assert int(kv.alloc.count[0]) == 1
    # crossing the boundary allocates one more
    for _ in range(7):
        kv = kvcache.advance(kv, active)
    kv = kvcache.ensure_pages(kv, active)
    assert int(kv.alloc.count[0]) == 2
    # release reclaims the whole chunk in O(1)
    kv = kvcache.release_slot(kv, 0)
    assert int(kv.alloc.count[0]) == 0 and int(kv.alloc.watermark[0]) == 0
    assert int(kv.lengths[0]) == 0
