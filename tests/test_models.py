"""Per-arch smoke tests (reduced configs) + decode/prefill consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS, SHAPES, applicable, get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import make_synthetic_batch
from repro.models import build_model
from repro.models.common import param_count
from repro.models.model_zoo import input_specs

ARCHS = sorted(CONFIGS)
SMOKE_SHAPE = ShapeConfig(name="smoke", seq_len=16, global_batch=2, kind="train")


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, rng):
    """REDUCED config of the same family: one forward + loss on CPU,
    asserting output shapes and no NaNs (the full config is exercised only by
    the dry-run)."""
    cfg = CONFIGS[arch].reduced()
    model = build_model(cfg)
    params = model.init(rng)
    assert param_count(params) > 0
    batch = {}
    for k, v in input_specs(cfg, SMOKE_SHAPE).items():
        if v.dtype == jnp.int32:
            batch[k] = jax.random.randint(rng, v.shape, 0,
                                          min(cfg.vocab_size, 100))
        else:
            batch[k] = jax.random.normal(rng, v.shape, v.dtype) * 0.2
    logits, aux = model.forward(params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = model.loss(params, batch)
    assert jnp.isfinite(loss)
    # one gradient step exists and is finite
    from repro.models.common import split_params
    values, axes = split_params(params)
    g = jax.grad(lambda v: model.loss_v(v, axes, batch)[0])(values)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_consistency(arch, rng):
    """Token-by-token decode must reproduce the teacher-forced forward."""
    cfg = CONFIGS[arch].reduced()
    model = build_model(cfg)
    params = model.init(rng)
    S = 10
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.embeds_input and cfg.family == "encdec":
        batch["embeds"] = jax.random.normal(
            rng, (2, 8, cfg.d_model), jnp.float32) * 0.3
    if cfg.embeds_input and cfg.family != "encdec":
        pytest.skip("vlm trains on embeds; decode covered via dense family")
    logits_full, _ = model.forward(params, batch)

    if cfg.family == "encdec":
        from repro.models import encdec
        cache, _ = encdec.encdec_init_cache(cfg, 2, S + 2, enc_len=8)
        cache = encdec.encdec_prefill_cross(
            params, cache, batch["embeds"], jnp.full((2,), 8, jnp.int32), cfg)
        step = lambda c, t: encdec.encdec_decode_step(params, c, t, cfg)
    else:
        cache, _ = model.init_cache(2, S + 2)
        step = lambda c, t: model.decode_step(params, c, t)

    errs = []
    for t in range(S):
        lg, cache = step(cache, tokens[:, t])
        errs.append(float(jnp.max(jnp.abs(lg - logits_full[:, t]))))
    assert max(errs) < 5e-3, (arch, errs)


def test_prefill_then_decode_dense(rng):
    cfg = CONFIGS["qwen2.5-14b"].reduced()
    model = build_model(cfg)
    params = model.init(rng)
    S = 12
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, S), 0,
                                cfg.vocab_size)
    logits_full, _ = model.forward(params, {"tokens": tokens})
    half = 7
    lg, cache = model.prefill(params, {"tokens": tokens[:, :half]}, S + 2)
    np.testing.assert_allclose(lg, logits_full[:, half - 1], atol=5e-4,
                               rtol=1e-3)
    for t in range(half, S):
        lg, cache = model.decode_step(params, cache, tokens[:, t])
        np.testing.assert_allclose(lg, logits_full[:, t], atol=5e-3, rtol=1e-2)


def test_hybrid_prefill_ring_cache_past_window(rng):
    """Prefill longer than the local window, then decode across the ring."""
    cfg = CONFIGS["recurrentgemma-9b"].reduced()   # window 16
    model = build_model(cfg)
    params = model.init(rng)
    S = 26
    tokens = jax.random.randint(jax.random.PRNGKey(3), (2, S), 0,
                                cfg.vocab_size)
    logits_full, _ = model.forward(params, {"tokens": tokens})
    half = 22
    lg, cache = model.prefill(params, {"tokens": tokens[:, :half]}, 64)
    np.testing.assert_allclose(lg, logits_full[:, half - 1], atol=5e-3,
                               rtol=1e-2)
    for t in range(half, S):
        lg, cache = model.decode_step(params, cache, tokens[:, t])
        np.testing.assert_allclose(lg, logits_full[:, t], atol=5e-3, rtol=1e-2)


def test_head_padding_is_exact(rng):
    """Same seed, padded vs unpadded: identical param values on real heads,
    identical logits (pad heads are zero + masked)."""
    base = CONFIGS["llama3.2-3b"].reduced()        # 4 heads, pad multiple 1
    padded = dataclasses.replace(base, head_pad_multiple=8)
    m0, m1 = build_model(base), build_model(padded)
    p0, p1 = m0.init(rng), m1.init(rng)
    tokens = jax.random.randint(jax.random.PRNGKey(4), (2, 12), 0,
                                base.vocab_size)
    l1, _ = m1.forward(p1, {"tokens": tokens})
    # pad-head weights are zero (heads axis is dim 1 of the stacked wq)
    wq = p1["layers"]["attn"]["wq"].value       # (L, d, padded_heads, hd)
    assert wq.shape[2] == 8
    assert float(jnp.abs(wq[:, :, 4:, :]).max()) == 0.0
    # decode matches forward under padding
    cache, _ = m1.init_cache(2, 14)
    for t in range(12):
        lg, cache = m1.decode_step(p1, cache, tokens[:, t])
        np.testing.assert_allclose(lg, l1[:, t], atol=5e-3, rtol=1e-2)


def test_long_context_applicability_matrix():
    """long_500k runs only for sub-quadratic archs; decode shapes exist for
    all (decoder-bearing) archs."""
    long_ok = {a for a in ARCHS
               if applicable(CONFIGS[a], SHAPES["long_500k"])[0]}
    assert long_ok == {"mamba2-130m", "recurrentgemma-9b"}
    for a in ARCHS:
        ok, _ = applicable(CONFIGS[a], SHAPES["decode_32k"])
        assert ok
    # 32 runnable cells of the nominal 40 (8 long_500k skips)
    total = sum(applicable(CONFIGS[a], SHAPES[s])[0]
                for a in ARCHS for s in SHAPES)
    assert total == 32


def test_moe_reference_routing_topk(rng):
    from repro.models.moe import moe_reference, moe_init
    cfg = CONFIGS["phi3.5-moe-42b-a6.6b"].reduced()
    p = moe_init(rng, cfg)
    vals = {k: v.value for k, v in p.items()}
    x = jax.random.normal(rng, (32, cfg.d_model)) * 0.5
    y, aux = moe_reference(vals, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) > 0.5    # load-balance loss near 1 for uniform-ish routing
