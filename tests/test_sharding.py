"""Sharding rules, divisibility guard, ZeRO-1 spec, hlocost analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (
    LOGICAL_RULES, ShardingCtx, logical_sharding, logical_spec,
    param_sharding_tree, with_logical_constraint, zero1_spec)
from repro.launch.hlocost import analyze


@pytest.fixture(scope="module")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_logical_spec_basic(mesh11):
    with ShardingCtx(mesh11):
        assert logical_spec("batch", "seq", "embed") == \
            P(("data",), None, None)
        assert logical_spec("fsdp", "ffn") == P("data", "model")


def test_divisibility_guard_drops_uneven(mesh11):
    # with a (1,1) mesh every size divides; emulate with rules math instead
    with ShardingCtx(mesh11):
        # shape divides trivially -> axes kept
        assert logical_spec("heads", shape=(8,)) == P("model")


def test_pod_axis_dropped_single_pod(mesh11):
    with ShardingCtx(mesh11):
        # "batch" maps to ("pod","data"); pod is absent -> dropped
        sp = logical_spec("batch")
        assert sp == P(("data",))


def test_constraint_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = with_logical_constraint(x, "batch", "embed")
    np.testing.assert_array_equal(x, y)


def test_constraint_rank_mismatch_raises(mesh11):
    with ShardingCtx(mesh11):
        with pytest.raises(ValueError):
            with_logical_constraint(jnp.ones((2, 2)), "batch")


def test_unknown_logical_axis_raises(mesh11):
    with ShardingCtx(mesh11):
        with pytest.raises(KeyError):
            logical_spec("no_such_axis")


def test_zero1_spec(mesh11):
    # unsharded dim that divides -> gains the data axis
    sp = zero1_spec(P(None, "model"), (8, 4), mesh11, axis="data")
    assert sp == P("data", "model")
    # already using data -> unchanged
    sp = zero1_spec(P("data", None), (8, 4), mesh11, axis="data")
    assert sp == P("data", None)


def test_rules_have_no_duplicate_mesh_axis_per_param():
    """Every param's logical axes must resolve to distinct mesh axes."""
    from repro.configs import CONFIGS
    from repro.models import build_model
    from repro.models.common import split_params
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for name in ("qwen2.5-14b", "qwen3-moe-235b-a22b", "mamba2-130m",
                 "recurrentgemma-9b", "seamless-m4t-large-v2"):
        cfg = CONFIGS[name].reduced()
        model = build_model(cfg)
        values, axes = model.param_specs()
        with ShardingCtx(mesh):
            flat = jax.tree_util.tree_flatten(
                axes, is_leaf=lambda v: isinstance(v, tuple) and all(
                    a is None or isinstance(a, str) for a in v))[0]
            for ax in flat:
                spec = logical_spec(*ax)
                seen = []
                for e in spec:
                    if e is None:
                        continue
                    es = e if isinstance(e, tuple) else (e,)
                    for a in es:
                        assert a not in seen, (name, ax, spec)
                        seen.append(a)


# ---------------------------------------------------------------------------
# hlocost: trip-count-aware analysis
# ---------------------------------------------------------------------------

def test_hlocost_counts_scan_trip_counts():
    def body(x, w):
        return jnp.tanh(x @ w), ()

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    def unrolled(x, ws):
        for i in range(8):
            x, _ = body(x, ws[i])
        return x

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    a_s = analyze(jax.jit(scanned).lower(x, ws).compile().as_text())
    a_u = analyze(jax.jit(unrolled).lower(x, ws).compile().as_text())
    expect = 8 * 2 * 64 * 128 * 128
    assert abs(a_s["flops"] - expect) / expect < 0.02
    assert abs(a_u["flops"] - expect) / expect < 0.02
    # bytes within 2x of each other (same program, different structure)
    assert 0.5 < a_s["bytes"] / a_u["bytes"] < 2.0


def test_hlocost_dot_contracting_dims():
    def f(a, b):
        return jnp.einsum("ij,kj->ik", a, b)      # contract j=256

    a = jax.ShapeDtypeStruct((32, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    r = analyze(jax.jit(f).lower(a, b).compile().as_text())
    expect = 2 * 32 * 64 * 256
    assert abs(r["flops"] - expect) / expect < 0.02
